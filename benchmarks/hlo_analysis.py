"""Trip-count-aware HLO cost analyzer (the dry-run 'profiler').

``compiled.cost_analysis()`` counts a while-loop body ONCE (verified in this
container: a 10-step scan of matmuls reports 1 matmul of flops), so scanned
models would be undercounted by ~num_layers. This analyzer parses the
post-SPMD optimized HLO text, extracts ``known_trip_count`` from each while's
backend_config, and accumulates per-device:

- flops: dot ops (2*B*M*N*K from operand shapes + contracting dims),
- bytes: operands+outputs of every materializing instruction (post-fusion,
  each remaining instruction is ~one kernel; bitcast/tuple/parameter/constant
  are free),
- collective bytes by op type (all-gather / all-reduce / reduce-scatter /
  all-to-all / collective-permute), operand sizes,

all multiplied through the while-loop nesting. Shapes in the partitioned
module are per-device, so results feed the per-chip roofline directly.
"""
from __future__ import annotations

import json
import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "token": 0, "c128": 16,
}

SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
FREE_OPS = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
            "after-all", "partition-id", "replica-id",
            # copies are CPU aliasing/layout artifacts; XLA:TPU buffer
            # assignment aliases loop-carried buffers in place
            "copy", "copy-start", "copy-done"}
COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def shape_bytes(text: str) -> int:
    """Sum byte sizes of every shape literal in ``text``."""
    total = 0
    for dt, dims in SHAPE_RE.findall(text):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


@dataclass
class Instr:
    name: str
    opcode: str
    result_text: str  # result shape portion
    operands: List[str]
    raw: str


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, float] = field(default_factory=lambda: defaultdict(float))

    def __iadd__(self, o: "Cost"):
        self.flops += o.flops
        self.bytes += o.bytes
        for k, v in o.coll.items():
            self.coll[k] += v
        return self

    def scaled(self, k: float) -> "Cost":
        c = Cost(self.flops * k, self.bytes * k)
        for key, v in self.coll.items():
            c.coll[key] = v * k
        return c

    @property
    def collective_bytes(self) -> float:
        return sum(self.coll.values())


INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.-]+)\s*=\s*(\([^=]*?\)|[^(]*?)\s*"
    r"([\w-]+)\((.*)$")


def _fusion_bytes(inst: "Instr", sub_instrs: List["Instr"],
                  shapes: Dict[str, int]) -> int:
    """HBM traffic at a fusion boundary: output + per-operand, where an
    operand that is only dynamic-sliced/gathered inside the fused computation
    is charged at the slice size (stacked scan weights!), not full size."""
    out_b = shape_bytes(inst.result_text)
    UNARY_FREE = ("convert", "copy", "bitcast", "reshape", "transpose",
                  "broadcast")
    # pure dtype/layout fusions: TPU fuses these into the consumer (CPU dot
    # legalization materializes f32 copies of bf16 operands — not real on TPU)
    real_ops = [si.opcode for si in sub_instrs
                if si.opcode not in FREE_OPS and si.opcode not in UNARY_FREE]
    if not real_ops:
        return 0
    pname = {}
    for si in sub_instrs:
        if si.opcode == "parameter":
            m = re.search(r"parameter\((\d+)", si.raw)
            if m:
                pname[si.name] = int(m.group(1))
    # resolve unary convert/copy chains back to their source parameter
    alias: Dict[str, str] = {}

    def resolve(name: Optional[str]) -> Optional[str]:
        seen = set()
        while name in alias and name not in seen:
            seen.add(name)
            name = alias[name]
        return name

    for si in sub_instrs:
        if si.opcode in UNARY_FREE and len(si.operands) == 1:
            alias[si.name] = si.operands[0]
    # param index -> slice-read / in-place-update size (vs full-buffer use)
    sliced: Dict[int, int] = {}
    direct_use: Dict[int, bool] = {}
    dus_bytes = 0  # in-place updates: output aliases the input buffer
    for si in sub_instrs:
        if si.opcode in UNARY_FREE:
            continue
        if si.opcode in ("dynamic-slice", "gather", "slice"):
            src = resolve(si.operands[0]) if si.operands else None
            if src in pname:
                idx = pname[src]
                sliced[idx] = sliced.get(idx, 0) + shape_bytes(si.result_text)
        elif si.opcode == "dynamic-update-slice":
            src = resolve(si.operands[0]) if si.operands else None
            upd = (shape_bytes_of(resolve(si.operands[1]), sub_instrs)
                   if len(si.operands) > 1 else 0)
            if src in pname:
                idx = pname[src]
                sliced[idx] = sliced.get(idx, 0) + upd
                dus_bytes += shape_bytes(si.result_text)
            for o in si.operands[1:]:
                ro = resolve(o)
                if ro in pname:
                    direct_use[pname[ro]] = True
        else:
            for o in si.operands:
                ro = resolve(o)
                if ro in pname:
                    direct_use[pname[ro]] = True
    if dus_bytes:
        # output aliases the updated buffer(s): only the slice region is new
        # (dus_bytes can exceed out_b when the update path changes dtype)
        out_b = max(out_b - dus_bytes, 0) + sum(sliced.values())
    total = out_b
    for i, opnd in enumerate(inst.operands):
        if i in sliced and not direct_use.get(i, False):
            total += sliced[i]
        else:
            total += shapes.get(opnd, 0)
    return total


def shape_bytes_of(name: str, instrs: List["Instr"]) -> int:
    for si in instrs:
        if si.name == name:
            return shape_bytes(si.result_text)
    return 0


def _parse_computations(hlo: str) -> Dict[str, List[Instr]]:
    comps: Dict[str, List[Instr]] = {}
    cur: Optional[str] = None
    hlo = re.sub(r"/\*.*?\*/", "", hlo)  # strip /*index=N*/ comments
    for line in hlo.splitlines():
        if not line.startswith(" ") and "{" in line and ("%" in line or
                                                         line.startswith("ENTRY")):
            m = re.match(r"^(?:ENTRY\s+)?%?([\w.-]+)", line.strip())
            if m:
                cur = m.group(1)
                comps[cur] = []
                if "ENTRY" in line:
                    comps["__entry__"] = comps[cur]
            continue
        if cur is None:
            continue
        m = INSTR_RE.match(line)
        if m:
            name, result, opcode, rest = m.groups()
            ops = re.findall(r"%([\w.-]+)", rest.split(", metadata=")[0]
                             .split(", backend_config=")[0]
                             .split(", calls=")[0])
            comps[cur].append(Instr(name, opcode, result, ops, line))
    return comps


def _dot_flops(inst: Instr, shapes: Dict[str, int],
               dims_of: Dict[str, List[int]]) -> float:
    """2 * prod(all dims) / prod(contracted) style: use operand dims."""
    m = re.search(r"dot\(([^)]*)\)", inst.raw)
    lhs_rhs = re.findall(r"%([\w.-]+)", m.group(1)) if m else inst.operands[:2]
    lhs_dims = dims_of.get(lhs_rhs[0], [])
    rhs_dims = dims_of.get(lhs_rhs[1], []) if len(lhs_rhs) > 1 else []
    cdim = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", inst.raw)
    bdim = re.search(r"lhs_batch_dims=\{([\d,]*)\}", inst.raw)
    contract = [int(x) for x in cdim.group(1).split(",")] if cdim and \
        cdim.group(1) else []
    batch = [int(x) for x in bdim.group(1).split(",")] if bdim and \
        bdim.group(1) else []
    K = 1
    for d in contract:
        if d < len(lhs_dims):
            K *= lhs_dims[d]
    B = 1
    for d in batch:
        if d < len(lhs_dims):
            B *= lhs_dims[d]
    lhs_n = 1
    for d in lhs_dims:
        lhs_n *= d
    rhs_n = 1
    for d in rhs_dims:
        rhs_n *= d
    M = lhs_n // max(K * B, 1)
    N = rhs_n // max(K * B, 1)
    return 2.0 * B * M * N * K


def analyze(hlo_text: str) -> Cost:
    comps = _parse_computations(hlo_text)

    # per-computation: instruction result dims + bytes
    def inst_dims(inst: Instr) -> List[int]:
        m = SHAPE_RE.search(inst.result_text)
        if not m:
            return []
        return [int(x) for x in m.group(2).split(",")] if m.group(2) else []

    memo: Dict[str, Cost] = {}

    def comp_cost(name: str) -> Cost:
        if name in memo:
            return memo[name]
        memo[name] = Cost()  # break cycles
        total = Cost()
        instrs = comps.get(name, [])
        shapes = {i.name: shape_bytes(i.result_text) for i in instrs}
        dims_of = {i.name: inst_dims(i) for i in instrs}
        for inst in instrs:
            op = inst.opcode
            if op == "while":
                body = re.search(r"body=%?([\w.-]+)", inst.raw)
                trip = re.search(r'known_trip_count[\\"{:\s]+n[\\":\s]+(\d+)',
                                 inst.raw)
                n = float(trip.group(1)) if trip else 1.0
                if body:
                    total += comp_cost(body.group(1)).scaled(n)
                continue
            if op in ("call", "conditional"):
                for sub in re.findall(r"(?:to_apply|calls)=%?([\w.-]+)",
                                      inst.raw):
                    total += comp_cost(sub)
                continue
            if op in FREE_OPS:
                continue
            if op == "fusion":
                sub = re.search(r"calls=%?([\w.-]+)", inst.raw)
                if sub:
                    # fused internals: flops count, bytes stay in VMEM/regs
                    total += Cost(flops=comp_cost(sub.group(1)).flops)
                    total += Cost(bytes=float(
                        _fusion_bytes(inst, comps.get(sub.group(1), []),
                                      shapes)))
                    continue
            # slice-like ops touch only the slice region, not the full buffer
            out_b = shape_bytes(inst.result_text)
            if op == "dynamic-slice" or op == "slice":
                io_bytes = 2 * out_b
            elif op == "dynamic-update-slice":
                upd = shapes.get(inst.operands[1], 0) if len(
                    inst.operands) > 1 else 0
                io_bytes = 2 * upd
            elif op == "gather":
                idx = shapes.get(inst.operands[1], 0) if len(
                    inst.operands) > 1 else 0
                io_bytes = 2 * out_b + idx
            elif op == "scatter":
                upd = shapes.get(inst.operands[-1], 0)
                io_bytes = 3 * upd
            else:
                io_bytes = out_b + sum(shapes.get(o, 0) for o in inst.operands)
            c = Cost(bytes=float(io_bytes))
            if op == "dot":
                c.flops = _dot_flops(inst, shapes, dims_of)
            elif op == "convolution":
                out_n = 1
                for d in dims_of.get(inst.name, []):
                    out_n *= d
                k_n = 1
                for d in dims_of.get(inst.operands[1], [1]):
                    k_n *= d
                spatial = max(k_n // max(dims_of.get(inst.operands[1], [1])[0], 1), 1)
                c.flops = 2.0 * out_n * spatial
            for coll in COLLECTIVES:
                if op == coll:
                    opnd = float(sum(shapes.get(o, 0) for o in inst.operands))
                    if opnd == 0.0:  # operands defined in another scope
                        opnd = float(shape_bytes(inst.result_text))
                    c.coll[coll] = opnd
            total += c
        # fusion subcomputations contribute flops only (bytes stay internal)
        memo[name] = total
        return total

    # fusion computations: bytes inside are VMEM-internal -> zero their bytes
    def comp_cost_fusion_safe(name: str) -> Cost:
        return comp_cost(name)

    entry = comps.get("__entry__")
    if entry is None:
        raise ValueError("no ENTRY computation found")
    # find entry computation name
    entry_name = next(k for k, v in comps.items()
                      if v is entry and k != "__entry__")
    return comp_cost(entry_name)


def analyze_file(path: str) -> Cost:
    with open(path) as f:
        return analyze(f.read())


if __name__ == "__main__":
    import sys
    c = analyze_file(sys.argv[1])
    print(json.dumps({"flops": c.flops, "bytes": c.bytes,
                      "collectives": dict(c.coll)}, indent=1))
